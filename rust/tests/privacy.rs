//! Privacy properties (paper §4, Appendix A.4).
//!
//! Information-theoretic privacy cannot be "tested" directly, but its two
//! load-bearing ingredients can:
//!
//! 1. **MDS structure** — the bottom T×T submatrices of the encoding
//!    matrix U are invertible for every T-subset of workers, so the masks
//!    Z/V fully randomize any T shares (the core of the A.4 proof).
//! 2. **Statistical indistinguishability** — the distribution of any T
//!    shares is the same whatever the dataset is; we check marginal
//!    uniformity and dataset-independence empirically.
//!
//! Plus the negative control: K+T shares DO determine the data (decoding
//! works), i.e. the threshold is tight.

use codedml::coding::{CodingParams, Encoder};
use codedml::field::{eval_poly, interpolate, PrimeField, PAPER_PRIME};
use codedml::mpc::ShamirScheme;
use codedml::util::Rng;

/// Gaussian-elimination rank over F_p (test-local helper).
fn rank(field: &PrimeField, mut m: Vec<Vec<u64>>) -> usize {
    let rows = m.len();
    if rows == 0 {
        return 0;
    }
    let cols = m[0].len();
    let mut rank = 0;
    let mut col = 0;
    while rank < rows && col < cols {
        let pivot = (rank..rows).find(|&r| m[r][col] != 0);
        match pivot {
            None => {
                col += 1;
            }
            Some(p) => {
                m.swap(rank, p);
                let inv = field.inv(m[rank][col]);
                for c in col..cols {
                    m[rank][c] = field.mul(m[rank][c], inv);
                }
                for r in 0..rows {
                    if r != rank && m[r][col] != 0 {
                        let factor = m[r][col];
                        for c in col..cols {
                            let sub = field.mul(factor, m[rank][c]);
                            m[r][c] = field.sub(m[r][c], sub);
                        }
                    }
                }
                rank += 1;
                col += 1;
            }
        }
    }
    rank
}

/// Every T-subset of U's bottom block is invertible (Lemma 2 of Yu et al.
/// via A.4) — checked exhaustively for a moderate configuration.
#[test]
fn bottom_submatrix_is_mds_for_all_t_subsets() {
    let field = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (10usize, 2usize, 2usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let enc = Encoder::new(field, params);
    for a in 0..n {
        for b in a + 1..n {
            let cols = [a, b];
            let sub: Vec<Vec<u64>> = (0..t)
                .map(|mask_row| {
                    cols.iter()
                        .map(|&w| enc.u_column(w)[k + mask_row])
                        .collect()
                })
                .collect();
            assert_eq!(rank(&field, sub), t, "singular bottom block for workers {a},{b}");
        }
    }
}

/// Any T coded shares look uniform regardless of the dataset: encode two
/// very different datasets with fresh masks and compare the first share's
/// histogram — both must match the uniform distribution.
#[test]
fn t_shares_are_dataset_independent_uniform() {
    let field = PrimeField::new(PAPER_PRIME);
    let params = CodingParams::new(7, 1, 2, 1).unwrap();
    let enc = Encoder::new(field, params);
    let (m, d) = (1usize, 16usize);
    let zeros = vec![0u64; m * d];
    let spikes: Vec<u64> = (0..m * d).map(|_| field.modulus() - 1).collect();

    let buckets = 16;
    let trials = 4000;
    let mut h_zero = vec![0usize; buckets];
    let mut h_spike = vec![0usize; buckets];
    let mut rng = Rng::new(99);
    for _ in 0..trials {
        let sz = enc.encode_dataset(&zeros, m, d, &mut rng);
        let ss = enc.encode_dataset(&spikes, m, d, &mut rng);
        let bucket = |v: u64| (v as u128 * buckets as u128 / field.modulus() as u128) as usize;
        h_zero[bucket(sz[3].data[0])] += 1;
        h_spike[bucket(ss[3].data[0])] += 1;
    }
    let expected = trials as f64 / buckets as f64;
    let tol = 5.0 * expected.sqrt();
    for b in 0..buckets {
        assert!((h_zero[b] as f64 - expected).abs() < tol, "zero[{b}]={}", h_zero[b]);
        assert!((h_spike[b] as f64 - expected).abs() < tol, "spike[{b}]={}", h_spike[b]);
    }
}

/// Tightness: K+T shares of the dataset polynomial DO determine the data
/// (that is how decoding works), so the privacy threshold T is sharp.
#[test]
fn k_plus_t_shares_reveal_the_data() {
    let field = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (10, 2, 1);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let enc = Encoder::new(field, params);
    let mut rng = Rng::new(5);
    let (m, d) = (4, 3);
    let xq = field.random_matrix(&mut rng, m, d);
    let shares = enc.encode_dataset(&xq, m, d, &mut rng);
    let block = m / k * d;
    for e in 0..block {
        let pts: Vec<u64> = enc.points.alphas[..k + t].to_vec();
        let vals: Vec<u64> = shares[..k + t].iter().map(|s| s.data[e]).collect();
        let coeffs = interpolate(&field, &pts, &vals).unwrap();
        let recovered = eval_poly(&field, &coeffs, enc.points.betas[0]);
        assert_eq!(recovered, xq[e], "entry {e} should be recoverable from K+T shares");
    }
}

/// Weight shares re-randomize every iteration: observing the same worker
/// across iterations reveals nothing about whether w changed (the Melis
/// et al. leakage the paper closes by encoding W̄ too).
#[test]
fn weight_shares_rerandomize_across_iterations() {
    let field = PrimeField::new(PAPER_PRIME);
    let params = CodingParams::new(10, 3, 1, 1).unwrap();
    let enc = Encoder::new(field, params);
    let mut rng = Rng::new(11);
    let wq = field.random_matrix(&mut rng, 8, 1);
    let s1 = enc.encode_weights(&wq, 8, 1, &mut rng);
    let s2 = enc.encode_weights(&wq, 8, 1, &mut rng);
    assert_ne!(s1[0].data, s2[0].data);

    let buckets = 8;
    let trials = 4000;
    let mut hist = vec![0usize; buckets];
    for _ in 0..trials {
        let s = enc.encode_weights(&wq, 8, 1, &mut rng);
        let v = s[0].data[0];
        hist[(v as u128 * buckets as u128 / field.modulus() as u128) as usize] += 1;
    }
    let expected = trials as f64 / buckets as f64;
    for (b, &h) in hist.iter().enumerate() {
        assert!((h as f64 - expected).abs() < 5.0 * expected.sqrt(), "bucket {b}: {h}");
    }
}

/// Cross-session isolation, statistical half (the serve layer's privacy
/// contract): a worker serving two concurrent sessions observes one
/// share from each. With per-session mask streams — what the scheduler
/// builds — that combined view is jointly randomized: even the
/// *difference* of the two shares is uniform. Had the sessions shared a
/// mask stream, encoding all-zeros in session A and all-(p−1) in session
/// B would make the difference a constant, and colluding workers could
/// compare datasets across jobs.
#[test]
fn colluding_workers_across_two_sessions_learn_nothing() {
    let field = PrimeField::new(PAPER_PRIME);
    let params = CodingParams::new(7, 1, 2, 1).unwrap();
    let enc = Encoder::new(field, params);
    let (m, d) = (1usize, 16usize);
    let zeros = vec![0u64; m * d];
    let spikes: Vec<u64> = (0..m * d).map(|_| field.modulus() - 1).collect();

    // Two sessions, two independent mask streams.
    let mut rng_a = Rng::new(101);
    let mut rng_b = Rng::new(202);

    let buckets = 16;
    let trials = 4000;
    let mut h_diff = vec![0usize; buckets];
    for _ in 0..trials {
        let sa = enc.encode_dataset(&zeros, m, d, &mut rng_a);
        let sb = enc.encode_dataset(&spikes, m, d, &mut rng_b);
        // Worker 3 colludes with itself across sessions: its view is the
        // pair (sa[3], sb[3]).
        let diff = field.sub(sa[3].data[0], sb[3].data[0]);
        h_diff[(diff as u128 * buckets as u128 / field.modulus() as u128) as usize] += 1;
    }
    let expected = trials as f64 / buckets as f64;
    let tol = 5.0 * expected.sqrt();
    for (b, &h) in h_diff.iter().enumerate() {
        assert!((h as f64 - expected).abs() < tol, "diff bucket {b}: {h}");
    }
}

/// Cross-session isolation, structural half: the frames shipped to the
/// pool for session A never appear among session B's frames — for *any*
/// worker pair — even when both sessions encode the very same dataset.
/// This is the regression net for mask-stream sharing between sessions:
/// a sibling session must draw fresh masks, so every one of its shares
/// differs from every share of A's.
#[test]
fn session_shares_never_cross_worker_frames() {
    use codedml::coordinator::{CodedMlConfig, CodedMlSession, LogisticObjective};
    use codedml::data::synthetic_3v7;

    let ds = synthetic_3v7(60, 3);
    let cfg_a = CodedMlConfig { n: 8, k: 2, t: 1, seed: 42, ..Default::default() };
    let cfg_b = CodedMlConfig { seed: 43, ..cfg_a.clone() };
    let a = CodedMlSession::<LogisticObjective>::new_detached(cfg_a, &ds, 1).unwrap();
    let b = CodedMlSession::<LogisticObjective>::new_detached(cfg_b, &ds, 2).unwrap();
    for (wa, fa) in a.x_shares.iter().enumerate() {
        for (wb, fb) in b.x_shares.iter().enumerate() {
            assert_ne!(
                fa, fb,
                "session A's frame for worker {wa} shows up as session B's \
                 frame for worker {wb}"
            );
        }
    }
}

/// The Shamir baseline has the same sharpness: T+1 shares reconstruct,
/// and T shares are consistent with every candidate secret (perfect
/// secrecy's combinatorial core).
#[test]
fn shamir_threshold_is_sharp() {
    let field = PrimeField::new(PAPER_PRIME);
    let scheme = ShamirScheme::new(field, 5, 2);
    let mut rng = Rng::new(21);
    let secret = 424242u64;
    let shares = scheme.share(secret, &mut rng);
    let idx = [0usize, 1, 2];
    let picked: Vec<u64> = idx.iter().map(|&i| shares[i]).collect();
    assert_eq!(scheme.reconstruct(&idx, &picked), secret);
    for candidate in [0u64, 1, 999_999] {
        let pts = vec![0, scheme.points[0], scheme.points[1]];
        let vals = vec![candidate, shares[0], shares[1]];
        let poly = interpolate(&field, &pts, &vals).unwrap();
        assert!(poly.len() <= 3, "degree-2 polynomial exists for candidate {candidate}");
    }
}
