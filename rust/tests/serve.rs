//! The serve layer's tentpole guarantee, end to end: a session scheduled
//! onto a shared pool trains **bit-identically** to the same session
//! running alone on a dedicated cluster — on both transports, at several
//! thread counts, and through chaos kills of shared workers. LCC
//! decoding is exact on any fastest-R subset, so interleaving N jobs'
//! rounds (which only perturbs arrival order) must never change a
//! decoded gradient; these tests pin that entire argument.
//!
//! TCP scenarios spawn real `codedml --worker` processes on loopback via
//! `CARGO_BIN_EXE_codedml`, exactly as `transport_conformance.rs` does.

use std::io::{BufRead, BufReader};
use std::process::{Child, Command, Stdio};

use codedml::coordinator::{CodedMlSession, ModelKind, TrainReport};
use codedml::data::{synthetic_3v7, synthetic_planted_linear};
use codedml::serve::{JobSpec, Scheduler, ServeSpec};

/// The reference trajectory: the very same job built the ordinary way —
/// one session, one dedicated cluster — and trained to completion.
fn dedicated_report(job: &JobSpec) -> TrainReport {
    match job.cfg.model {
        ModelKind::Logistic => {
            let ds = synthetic_3v7(job.m, job.data_seed);
            let mut s = CodedMlSession::new(job.cfg.clone(), &ds).unwrap();
            s.train(job.cfg.iters, None).unwrap()
        }
        ModelKind::Linear => {
            let (ds, _) = synthetic_planted_linear(job.m, job.d, job.data_seed);
            let mut s = CodedMlSession::new_linear(job.cfg.clone(), &ds).unwrap();
            s.train(job.cfg.iters, None).unwrap()
        }
    }
}

/// Two heterogeneous sessions — different objectives, shapes, *and*
/// moduli (logistic on the 24-bit paper prime, linear on the 26-bit
/// one) — interleaved over one pool.
fn two_session_spec(par: usize, transport_block: &str) -> String {
    format!(
        r#"{{ {transport_block}"sessions": [
            {{ "name": "log", "m": 60, "data_seed": 3,
               "config": {{ "n": 8, "k": 2, "t": 1, "iters": 3,
                            "parallelism": {par} }} }},
            {{ "name": "lin", "m": 60, "d": 4, "data_seed": 9,
               "config": {{ "model": "linear", "n": 6, "k": 1, "t": 1,
                            "iters": 3, "parallelism": {par} }} }}
        ] }}"#
    )
}

/// Assert every session of `rep` matched its dedicated run bit-for-bit:
/// identical per-iteration losses and identical final weights.
fn assert_isolated(rep: &codedml::coordinator::ServeReport, jobs: &[JobSpec], ctx: &str) {
    assert_eq!(rep.misrouted, 0, "{ctx}: session routing must be airtight");
    assert_eq!(rep.sessions.len(), jobs.len());
    for (s, job) in rep.sessions.iter().zip(jobs) {
        assert_eq!(s.error, None, "{ctx}: session '{}' failed", s.name);
        let reference = dedicated_report(job);
        assert_eq!(
            s.report.iterations, reference.iterations,
            "{ctx}: session '{}' loss curve diverged from its dedicated run",
            s.name
        );
        assert_eq!(
            s.report.weights, reference.weights,
            "{ctx}: session '{}' weights diverged from its dedicated run",
            s.name
        );
    }
}

/// Tentpole, in-memory: at every thread count, each of two interleaved
/// mixed-modulus sessions is bit-identical to running alone.
#[test]
fn interleaved_sessions_match_dedicated_runs_on_memory_transport() {
    for par in [1usize, 2, 4] {
        let spec = ServeSpec::from_json(&two_session_spec(par, "")).unwrap();
        let jobs = spec.jobs.clone();
        assert_ne!(
            jobs[0].cfg.p, jobs[1].cfg.p,
            "the pair must exercise mixed moduli on one pool"
        );
        let mut sched = Scheduler::new(spec).unwrap();
        let rep = sched.run().unwrap();
        assert_eq!(rep.transport, "memory");
        assert_isolated(&rep, &jobs, &format!("memory, {par} thread(s)"));
        // The schedule genuinely interleaved: 3 rounds per session, and
        // no session dispatched twice before its sibling went once.
        let log = sched.dispatch_log();
        assert_eq!(log.len(), 6, "{log:?}");
        for wave in log.chunks(2) {
            let mut ids = wave.to_vec();
            ids.sort_unstable();
            assert_eq!(ids, [1, 2], "non-interleaved schedule: {log:?}");
        }
    }
}

/// A `codedml --worker` child on an ephemeral loopback port; killed and
/// reaped on drop so a failing assertion cannot leak processes.
struct WorkerProc {
    child: Child,
    addr: String,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_worker() -> WorkerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_codedml"))
        .args(["--worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line.trim().rsplit(' ').next().unwrap_or("").to_string();
    assert!(addr.contains(':'), "unexpected worker banner: {line:?}");
    WorkerProc { child, addr }
}

/// Tentpole, TCP: the same pair of sessions multiplexed over real worker
/// processes still matches the dedicated (in-memory) trajectories — the
/// wire changes nothing, the scheduling changes nothing.
#[test]
fn interleaved_sessions_match_dedicated_runs_on_tcp_transport() {
    for par in [1usize, 2, 4] {
        let procs: Vec<WorkerProc> = (0..8).map(|_| spawn_worker()).collect();
        let addrs = procs
            .iter()
            .map(|p| format!("\"{}\"", p.addr))
            .collect::<Vec<_>>()
            .join(", ");
        let transport = format!(r#""transport": "tcp", "tcp_workers": [{addrs}], "#);
        let spec = ServeSpec::from_json(&two_session_spec(par, &transport)).unwrap();
        let jobs = spec.jobs.clone();
        let mut sched = Scheduler::new(spec).unwrap();
        let rep = sched.run().unwrap();
        assert_eq!(rep.transport, "tcp");
        assert!(rep.wire_sent > 0 && rep.wire_received > 0, "tcp must account bytes");
        assert_isolated(&rep, &jobs, &format!("tcp, {par} thread(s)"));
    }
}

/// Chaos churn on the shared pool: two workers die mid-run under one
/// session's rounds (n=8, K=2, T=1 ⇒ R=7, slack 1 — two deaths force a
/// heal). The scheduler must revive them, rebuild *both* sessions'
/// engines on the replacements, and finish both jobs — still
/// bit-identical to clean dedicated runs, because heals re-ship the
/// exact original shares and LCC decoding is subset-independent.
#[test]
fn chaos_kill_of_shared_workers_heals_both_sessions_bit_identically() {
    let spec = ServeSpec::from_json(
        r#"{ "sessions": [
            { "name": "churned", "m": 60, "data_seed": 3,
              "config": { "n": 8, "k": 2, "t": 1, "iters": 3,
                          "chaos_failures": 2, "chaos_from_iter": 1,
                          "max_respawns": 2 } },
            { "name": "bystander", "m": 60, "data_seed": 5,
              "config": { "n": 8, "k": 2, "t": 1, "iters": 3 } }
        ] }"#,
    )
    .unwrap();
    // The reference runs are *clean*: chaos + healing must be invisible
    // in the trajectory, so compare against jobs with chaos stripped.
    let mut jobs = spec.jobs.clone();
    for j in jobs.iter_mut() {
        j.cfg.chaos_failures = 0;
        j.cfg.chaos_from_iter = 0;
        j.cfg.max_respawns = 0;
    }
    let mut sched = Scheduler::new(spec).unwrap();
    let rep = sched.run().unwrap();
    assert!(
        rep.respawns >= 1,
        "the chaos deaths must actually exercise the heal path: {rep:?}"
    );
    assert_isolated(&rep, &jobs, "memory + chaos churn");
}
