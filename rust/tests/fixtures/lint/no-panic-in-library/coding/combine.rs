//! Lint fixture: trips exactly `no-panic-in-library`.
//!
//! This file is never compiled — `rust/tests/lint.rs` feeds it to the
//! linter and asserts the rule fires here and nowhere else.

pub fn first(results: &[Option<u64>]) -> u64 {
    results[0].unwrap()
}
