//! Lint fixture: trips exactly `no-cross-session-state`.
//!
//! This file is never compiled — `rust/tests/lint.rs` feeds it to the
//! linter and asserts the rule fires here and nowhere else. The bug it
//! models: scheduler code taking a worker result it happens to hold and
//! pushing it straight into a round, skipping the cluster's session-id
//! check that keeps one job's results out of a sibling's decode.

pub fn drain_into(round: &mut Round, parked: Vec<StepResult>) {
    for res in parked {
        round.absorb(res);
    }
}
