//! Lint fixture: trips exactly `no-wallclock-nondeterminism`.
//!
//! This file is never compiled — `rust/tests/lint.rs` feeds it to the
//! linter and asserts the rule fires here and nowhere else.

use std::time::Instant;

pub fn elapsed_secs() -> f64 {
    let start = Instant::now();
    start.elapsed().as_secs_f64()
}
