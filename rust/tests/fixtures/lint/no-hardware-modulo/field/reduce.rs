//! Lint fixture: trips exactly `no-hardware-modulo`.
//!
//! This file is never compiled — `rust/tests/lint.rs` feeds it to the
//! linter and asserts the rule fires here and nowhere else.

pub fn reduce(x: u64, p: u64) -> u64 {
    x % p
}
