//! Lint fixture: trips exactly `no-plaintext-to-workers`.
//!
//! This file is never compiled — `rust/tests/lint.rs` feeds it to the
//! linter and asserts the rule fires here and nowhere else.

use crate::data::Dataset;

pub fn prepare(rows: &Dataset) -> usize {
    rows.m
}
