//! Lint fixture: trips exactly `no-stray-io`.
//!
//! This file is never compiled — `rust/tests/lint.rs` feeds it to the
//! linter and asserts the rule fires here and nowhere else.

pub fn log(msg: &str) {
    println!("{msg}");
}
