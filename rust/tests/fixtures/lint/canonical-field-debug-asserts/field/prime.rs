//! Lint fixture: trips exactly `canonical-field-debug-asserts`.
//!
//! This file is never compiled — `rust/tests/lint.rs` feeds it to the
//! linter and asserts the rule fires here and nowhere else.

pub struct PrimeField {
    pub p: u64,
}

impl PrimeField {
    pub fn add(&self, a: u64, b: u64) -> u64 {
        let s = a.wrapping_add(b);
        if s >= self.p {
            s - self.p
        } else {
            s
        }
    }
}
