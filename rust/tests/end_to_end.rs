//! End-to-end integration: private training tracks plaintext training and
//! hits the paper's accuracy regime; MPC baseline agrees with LCC on the
//! model it produces; stragglers and failures are tolerated up to the
//! design margins.

use codedml::cluster::{NetworkModel, StragglerModel};
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::{synthetic_3v7, synthetic_planted_linear};
use codedml::model::{LinearRegression, LogisticRegression};
use codedml::mpc::{BgwConfig, BgwGradientProtocol};

fn fast_cfg(n: usize, k: usize, t: usize) -> CodedMlConfig {
    CodedMlConfig {
        n,
        k,
        t,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..Default::default()
    }
}

/// Figure 3's claim at test scale: CPML accuracy ends within ~2% of
/// conventional LR after 25 iterations.
#[test]
fn private_training_matches_conventional_lr_accuracy() {
    let train = synthetic_3v7(240, 1);
    let test = synthetic_3v7(120, 2);

    // Conventional (plaintext, real sigmoid, no quantization).
    let mut plain = LogisticRegression::new(train.d);
    let eta = plain.lipschitz_lr(&train);
    for _ in 0..25 {
        plain.step(&train, eta);
    }
    let plain_acc = plain.accuracy(&test);

    // CodedPrivateML, Case-2-style (K = T).
    let mut sess = CodedMlSession::new(fast_cfg(13, 2, 2), &train).unwrap();
    let report = sess.train(25, Some(&test)).unwrap();
    let cpml_acc = report.final_accuracy().unwrap();

    assert!(plain_acc >= 0.88, "plaintext should learn: {plain_acc}");
    assert!(
        cpml_acc > plain_acc - 0.03,
        "CPML {cpml_acc} vs plaintext {plain_acc}"
    );
}

/// Convergence (Figure 4): the CPML loss curve decreases and approaches
/// the plaintext curve.
#[test]
fn loss_curve_tracks_plaintext() {
    let train = synthetic_3v7(240, 7);
    let mut sess = CodedMlSession::new(fast_cfg(10, 3, 1), &train).unwrap();
    let report = sess.train(15, None).unwrap();
    let losses: Vec<f64> = report.iterations.iter().map(|m| m.train_loss).collect();
    // Non-increasing within tolerance (stochastic quantization noise).
    for w in losses.windows(2) {
        assert!(w[1] <= w[0] + 0.02, "loss bump {} → {}", w[0], w[1]);
    }
    assert!(losses.last().unwrap() < &0.45, "final loss {losses:?}");

    let mut plain = LogisticRegression::new(train.d);
    let ds = train.take_rows_multiple_of(train.m, 3);
    let eta = plain.lipschitz_lr(&ds);
    for _ in 0..15 {
        plain.step(&ds, eta);
    }
    let plain_loss = plain.loss(&ds);
    assert!(
        (losses.last().unwrap() - plain_loss).abs() < 0.12,
        "cpml {} vs plain {plain_loss}",
        losses.last().unwrap()
    );
}

/// LCC and BGW implement the *same* learning algorithm: with matching
/// seeds and quantization parameters the two private protocols produce
/// models of equal quality (not bit-equal — different mask streams — but
/// statistically twins).
#[test]
fn mpc_and_lcc_produce_equivalent_models() {
    let train = synthetic_3v7(120, 3);
    let test = synthetic_3v7(60, 4);

    let mut lcc = CodedMlSession::new(fast_cfg(10, 3, 1), &train).unwrap();
    let lcc_rep = lcc.train(15, Some(&test)).unwrap();

    let mut bgw = BgwGradientProtocol::new(
        BgwConfig {
            n: 10,
            t: 1,
            net: NetworkModel::free(),
            straggler: StragglerModel::none(),
            ..Default::default()
        },
        &train.take_rows_multiple_of(120, 3),
    )
    .unwrap();
    let bgw_rep = bgw.train(15, Some(&test));

    let la = lcc_rep.final_accuracy().unwrap();
    let ba = bgw_rep.final_accuracy().unwrap();
    assert!((la - ba).abs() < 0.05, "lcc {la} vs bgw {ba}");
    let ll = lcc_rep.final_loss().unwrap();
    let bl = bgw_rep.final_loss().unwrap();
    assert!((ll - bl).abs() < 0.05, "lcc {ll} vs bgw {bl}");
}

/// Straggler slack: with N comfortably above the recovery threshold the
/// session absorbs heavy straggling without touching the trajectory.
#[test]
fn heavy_straggling_only_slows_modeled_time() {
    let train = synthetic_3v7(120, 9);
    let mut cfg_fast = fast_cfg(13, 3, 1); // threshold 10, slack 3
    cfg_fast.iters = 5;
    let mut cfg_slow = cfg_fast.clone();
    cfg_slow.straggler = StragglerModel { shift: 1.0, rate: 0.5, relative: true };

    let mut fast = CodedMlSession::new(cfg_fast, &train).unwrap();
    let mut slow = CodedMlSession::new(cfg_slow, &train).unwrap();
    let rf = fast.train(5, None).unwrap();
    let rs = slow.train(5, None).unwrap();
    assert_eq!(rf.weights, rs.weights, "trajectory must be straggler-invariant");
    assert!(
        rs.breakdown.comp_s > rf.breakdown.comp_s,
        "straggling must show up in modeled time: {} vs {}",
        rs.breakdown.comp_s,
        rf.breakdown.comp_s
    );
}

/// The `--threads` knob changes wall-clock only: a full training run with
/// the thread pool enabled is bit-identical to the serial run (masks and
/// stochastic quantization are drawn before every fan-out, and all merges
/// are exact field adds — see `util::par`).
#[test]
fn parallel_training_is_bit_exact_with_serial() {
    use codedml::util::Parallelism;
    let train = synthetic_3v7(120, 11);
    let serial = {
        let mut sess = CodedMlSession::new(fast_cfg(10, 3, 1), &train).unwrap();
        sess.train(6, None).unwrap()
    };
    for par in [Parallelism::from_count(2), Parallelism::from_count(4), Parallelism::Auto] {
        let mut cfg = fast_cfg(10, 3, 1);
        cfg.parallelism = par;
        let mut sess = CodedMlSession::new(cfg, &train).unwrap();
        let report = sess.train(6, None).unwrap();
        assert_eq!(report.weights, serial.weights, "par={par}");
        assert_eq!(report.bytes_sent, serial.bytes_sent);
        assert_eq!(report.bytes_received, serial.bytes_received);
    }
}

/// Remark 1 end to end: coded linear regression tracks plaintext gradient
/// descent on the same planted task — same trainer, different substrate —
/// and both recover w* to within the quantization floor.
#[test]
fn coded_linear_regression_tracks_plaintext_gd() {
    let (train, w_star) = synthetic_planted_linear(120, 8, 41);
    let cfg = CodedMlConfig {
        n: 10,
        k: 3,
        t: 1,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..CodedMlConfig::linear()
    };
    let mut sess = CodedMlSession::new_linear(cfg, &train).unwrap();
    let eta = sess.eta;
    let report = sess.train(30, None).unwrap();

    // Plaintext GD with the same step count on the raw data.
    let mut plain = LinearRegression::new(8);
    for _ in 0..30 {
        plain.step(&train.x, &train.y, 120, 8, eta);
    }
    let coded_err = LinearRegression::with_weights(report.weights.clone()).distance_to(&w_star);
    let plain_err = plain.distance_to(&w_star);
    assert!(coded_err < 0.15, "coded ‖w − w*‖ = {coded_err}");
    assert!(
        coded_err < plain_err + 0.1,
        "coded {coded_err} should track plaintext {plain_err}"
    );
    // MSE on the quantized view never increases (tolerance absorbs the
    // stochastic weight-quantization noise floor at the curve's bottom).
    let losses: Vec<f64> = report.iterations.iter().map(|m| m.train_loss).collect();
    for w in losses.windows(2) {
        assert!(w[1] <= w[0] + 1e-3, "loss bump {} → {}", w[0], w[1]);
    }
}

/// The overflow budget warning fires but training still completes when
/// non-strict; strict mode refuses to build the session.
#[test]
fn budget_enforcement_modes() {
    let train = synthetic_3v7(240, 5);
    let mut cfg = fast_cfg(10, 1, 2); // K=1: whole dataset in one block
    cfg.lc = 8; // deliberately blow the budget
    cfg.strict_budget = true;
    assert!(CodedMlSession::new(cfg.clone(), &train).is_err());
    cfg.strict_budget = false;
    // Builds (with a warning) — decoding may wrap, which is the point.
    let _ = CodedMlSession::new(cfg, &train).unwrap();
}

/// Recovery threshold arithmetic is enforced end to end: N below the
/// threshold is rejected at session construction.
#[test]
fn insufficient_workers_rejected_end_to_end() {
    let train = synthetic_3v7(60, 6);
    let cfg = CodedMlConfig { n: 9, k: 3, t: 1, ..Default::default() };
    assert!(CodedMlSession::new(cfg, &train).is_err());
}
