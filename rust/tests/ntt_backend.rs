//! Integration: the NTT coset coding backend must be a pure perf choice —
//! bit-identical training trajectories to the dense Lagrange path at every
//! thread count, visible in traces/reports, and a config error where the
//! modulus cannot host the coset.

use codedml::cluster::{NetworkModel, StragglerModel};
use codedml::coding::{CodingBackend, CodingBackendChoice};
use codedml::coordinator::{CodedMlConfig, CodedMlSession, Tracer};
use codedml::data::{synthetic_3v7, synthetic_planted_linear};
use codedml::field::{PRIME_NTT_25, PRIME_NTT_28};
use codedml::util::Parallelism;

fn ntt_cfg(backend: CodingBackendChoice) -> CodedMlConfig {
    CodedMlConfig {
        n: 10,
        k: 3,
        t: 1,
        p: PRIME_NTT_25,
        coding_backend: backend,
        straggler: StragglerModel::none(),
        net: NetworkModel::free(),
        ..Default::default()
    }
}

#[test]
fn ntt_trajectory_is_bit_identical_to_dense_at_every_thread_count() {
    // Same seed → same quantizations and mask draws; LCC decoding is exact
    // on either point layout, so the weight trajectories must agree to the
    // last bit — not approximately.
    let train = synthetic_3v7(120, 11);
    let test = synthetic_3v7(60, 12);
    let mut dense = CodedMlSession::new(ntt_cfg(CodingBackendChoice::Dense), &train).unwrap();
    let dense_rep = dense.train(6, Some(&test)).unwrap();
    assert_eq!(dense.coding_backend(), CodingBackend::Dense);
    assert_eq!(dense_rep.coding_backend, "dense");

    for threads in [1usize, 2, 4] {
        let mut cfg = ntt_cfg(CodingBackendChoice::Ntt);
        cfg.parallelism = Parallelism::from_count(threads);
        let mut ntt = CodedMlSession::new(cfg, &train).unwrap();
        let ntt_rep = ntt.train(6, Some(&test)).unwrap();
        assert_eq!(ntt.coding_backend(), CodingBackend::Ntt);
        assert_eq!(ntt_rep.coding_backend, "ntt");
        assert_eq!(
            dense_rep.weights, ntt_rep.weights,
            "ntt trajectory diverged at {threads} thread(s)"
        );
        for (a, b) in dense_rep.iterations.iter().zip(ntt_rep.iterations.iter()) {
            assert_eq!(a.train_loss, b.train_loss);
            assert_eq!(a.test_accuracy, b.test_accuracy);
        }
    }
}

#[test]
fn ntt_decode_is_exact_for_straggler_subsets() {
    // Whichever R-subset of the coset alphas arrives first, the
    // barycentric decode rows are exact — straggling may only change the
    // modeled timing, never the weights (mirror of the dense-path test in
    // coordinator::session).
    let train = synthetic_3v7(60, 5);
    let mut cfg_a = ntt_cfg(CodingBackendChoice::Ntt);
    cfg_a.n = 12;
    let mut cfg_b = cfg_a.clone();
    cfg_b.straggler = StragglerModel { shift: 0.5, rate: 2.0, relative: true };
    let ra = CodedMlSession::new(cfg_a, &train).unwrap().train(3, None).unwrap();
    let rb = CodedMlSession::new(cfg_b, &train).unwrap().train(3, None).unwrap();
    assert_eq!(ra.weights, rb.weights);
}

#[test]
fn step_trace_carries_the_coding_backend() {
    let train = synthetic_3v7(60, 7);
    let mut sess = CodedMlSession::new(ntt_cfg(CodingBackendChoice::Ntt), &train).unwrap();
    sess.set_tracer(Tracer::memory());
    sess.step().unwrap();
    let events = sess.tracer().events();
    let step = events
        .iter()
        .find(|e| e.get("event").and_then(|v| v.as_str()) == Some("step"))
        .expect("step event");
    assert_eq!(step.get("coding_backend").unwrap().as_str(), Some("ntt"));
}

#[test]
fn forcing_ntt_on_a_low_adicity_modulus_is_a_config_error() {
    // The paper's 24-bit prime has 2-adicity 1: no power-of-two subgroup
    // big enough for the alphas, so the session must refuse loudly (and
    // point at the NTT-friendly primes) instead of silently going dense.
    let train = synthetic_3v7(60, 9);
    let mut cfg = ntt_cfg(CodingBackendChoice::Ntt);
    cfg.p = codedml::field::PAPER_PRIME;
    let err = CodedMlSession::new(cfg, &train).unwrap_err().to_string();
    assert!(err.contains("2-adicity"), "{err}");
    assert!(err.contains(&PRIME_NTT_25.to_string()), "{err}");
}

#[test]
fn auto_backend_matches_dense_exactly_at_small_shapes() {
    // At (K+T = 4, N = 10) the cost model keeps Auto on the dense path
    // even on an NTT-friendly modulus — and Auto must then behave exactly
    // like Dense, standard point grid included.
    let train = synthetic_3v7(60, 13);
    let mut auto_s = CodedMlSession::new(ntt_cfg(CodingBackendChoice::Auto), &train).unwrap();
    let mut dense = CodedMlSession::new(ntt_cfg(CodingBackendChoice::Dense), &train).unwrap();
    assert_eq!(auto_s.coding_backend(), CodingBackend::Dense);
    let ra = auto_s.train(3, None).unwrap();
    let rd = dense.train(3, None).unwrap();
    assert_eq!(ra.weights, rd.weights);
}

#[test]
fn auto_backend_engages_ntt_at_large_shapes() {
    // K+T = 32 with N = 128 is past the crossover (butterflies beat the
    // 32×128 dense combine), so Auto must resolve to the coset layout on
    // its own. Linear model keeps d small so 128 in-memory workers stay
    // cheap; the 28-bit NTT prime has headroom for the linear scales.
    let (train, _) = synthetic_planted_linear(60, 4, 17);
    let cfg = CodedMlConfig {
        n: 128,
        k: 30,
        t: 2,
        r: 1,
        p: PRIME_NTT_28,
        straggler: StragglerModel::none(),
        net: NetworkModel::free(),
        ..CodedMlConfig::linear()
    };
    let mut sess = CodedMlSession::new_linear(cfg, &train).unwrap();
    assert_eq!(sess.coding_backend(), CodingBackend::Ntt);
    sess.step().unwrap();
}

#[test]
fn bounded_decode_cache_evicts_without_changing_the_trajectory() {
    // N = 12 at threshold 10 leaves real straggler slack, so the decoded
    // subsets follow thread-scheduling races from round to round; decode
    // exactness makes that invisible in the weights. The cap is a memory
    // knob only — and with cap 1, every miss after the first must evict,
    // so evictions = misses − 1 whatever the subset pattern was.
    let train = synthetic_3v7(120, 15);
    let mut capped = ntt_cfg(CodingBackendChoice::Dense);
    capped.n = 12;
    capped.decode_cache_cap = 1;
    let mut unbounded = capped.clone();
    unbounded.decode_cache_cap = 0;
    let rc = CodedMlSession::new(capped, &train).unwrap().train(6, None).unwrap();
    let ru = CodedMlSession::new(unbounded, &train).unwrap().train(6, None).unwrap();
    assert_eq!(rc.weights, ru.weights);
    assert_eq!(ru.decode_cache_evictions, 0);
    assert!(rc.decode_cache.1 >= 1, "at least the first decode misses");
    assert_eq!(rc.decode_cache_evictions, rc.decode_cache.1 - 1);
}
