//! Backend-conformance suite for the `Transport` seam: every backend —
//! the in-memory channel default and the TCP multi-process one — must
//! drive the identical dispatch/collect contract. Because LCC decoding is
//! exact for *any* fastest-R subset, the decoded gradients must be
//! bit-identical across backends at every thread count, no matter which
//! workers happened to answer first or over which medium the shares
//! travelled. The suite also pins the streaming-round behaviours
//! (early exit at R, late-result draining, mid-round worker death) to
//! both backends so a new transport cannot regress them silently.
//!
//! TCP scenarios spawn real `codedml --worker` processes on loopback via
//! `CARGO_BIN_EXE_codedml`, exactly as a deployment would.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use codedml::cluster::transport::TcpConfig;
use codedml::cluster::{Cluster, Supervisor, TransportConfig, TransportKind, WorkerOp, WorkerSpec};
use codedml::coding::{CodingParams, Decoder, Encoder, WorkerResult};
use codedml::compute::WorkerComputation;
use codedml::field::{PrimeField, PAPER_PRIME};
use codedml::util::timer::Deadline;
use codedml::util::{Parallelism, Rng};

/// A `codedml --worker` child process bound to an ephemeral loopback
/// port. Killed and reaped on drop so a failing assertion can't leak
/// processes into the test runner.
struct WorkerProc {
    child: Child,
    addr: String,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_worker() -> WorkerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_codedml"))
        .args(["--worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    // The worker prints exactly one banner line before accepting:
    //   worker listening on 127.0.0.1:PORT
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line.trim().rsplit(' ').next().unwrap_or("").to_string();
    assert!(addr.contains(':'), "unexpected worker banner: {line:?}");
    WorkerProc { child, addr }
}

fn spawn_workers(n: usize) -> Vec<WorkerProc> {
    (0..n).map(|_| spawn_worker()).collect()
}

fn tcp_config(procs: &[WorkerProc]) -> TransportConfig {
    TransportConfig {
        kind: TransportKind::Tcp,
        tcp: TcpConfig {
            workers: procs.iter().map(|p| p.addr.clone()).collect(),
            ..TcpConfig::default()
        },
    }
}

fn specs(n: usize, rows: usize, d: usize, coeffs: &[u64], par: Parallelism) -> Vec<WorkerSpec> {
    let f = PrimeField::new(PAPER_PRIME);
    (0..n)
        .map(|id| WorkerSpec {
            id,
            session: 0,
            kind: codedml::runtime::BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            field: f,
            rows,
            d,
            coeffs: coeffs.to_vec(),
            op: WorkerOp::Logistic,
            fail_from_iter: None,
            slow_ms: 0,
            par,
        })
        .collect()
}

/// Run `iters` dispatch/collect/decode rounds on a cluster and return the
/// decoded gradient blocks per iteration, always decoding the fastest-R
/// subset in arrival order.
fn run_rounds(
    cluster: &mut Cluster,
    enc: &Encoder,
    f: PrimeField,
    params: CodingParams,
    d: usize,
    w_shares_per_iter: &[Vec<Vec<u64>>],
) -> Vec<Vec<Vec<u64>>> {
    let need = params.recovery_threshold();
    let mut dec = Decoder::new(f, params, enc.points.clone());
    let mut decoded = Vec::new();
    for (iter, w_shares) in w_shares_per_iter.iter().enumerate() {
        cluster.dispatch(iter as u64, w_shares.clone()).unwrap();
        let round = cluster.collect_first(need, iter as u64).unwrap();
        assert!(round.ok(), "iter {iter}: {round:?}");
        let subset: Vec<WorkerResult> = round
            .results
            .iter()
            .take(need)
            .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
            .collect();
        decoded.push(dec.decode(&subset, d).unwrap());
    }
    decoded
}

/// Tentpole conformance: with identical shares, the decoded gradient of
/// every iteration is bit-identical on the in-memory backend, on the TCP
/// backend with real worker processes, and to the ground-truth direct
/// computation — at serial and multi-threaded worker parallelism alike.
#[test]
fn decoded_gradients_bit_identical_across_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (9usize, 2usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    assert!(n - params.recovery_threshold() >= 2, "want straggler slack");
    let (rows, d) = (4usize, 6usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(42);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    let iters = 3usize;
    let mut wqs = Vec::new();
    let mut w_shares_per_iter = Vec::new();
    for _ in 0..iters {
        let wq = f.random_matrix(&mut rng, d, 1);
        let shares: Vec<Vec<u64>> = enc
            .encode_weights(&wq, d, 1, &mut rng)
            .into_iter()
            .map(|s| s.data)
            .collect();
        wqs.push(wq);
        w_shares_per_iter.push(shares);
    }
    let wc = WorkerComputation::new(f, rows, d, coeffs.clone());

    for par in [Parallelism::Serial, Parallelism::from_count(2)] {
        let mut mem = Cluster::spawn(specs(n, rows, d, &coeffs, par)).unwrap();
        mem.load_data(x_shares.clone(), None).unwrap();
        let mem_decoded = run_rounds(&mut mem, &enc, f, params, d, &w_shares_per_iter);

        let procs = spawn_workers(n);
        let mut tcp = Cluster::connect(specs(n, rows, d, &coeffs, par), &tcp_config(&procs)).unwrap();
        assert_eq!(tcp.transport_name(), "tcp");
        tcp.load_data(x_shares.clone(), None).unwrap();
        let tcp_decoded = run_rounds(&mut tcp, &enc, f, params, d, &w_shares_per_iter);

        assert_eq!(mem_decoded, tcp_decoded, "backends diverged at par {par:?}");

        // Both equal ground truth on the true blocks, every iteration.
        let block = rows * d;
        for (iter, wq) in wqs.iter().enumerate() {
            for kk in 0..k {
                let truth = wc.compute(&xq[kk * block..(kk + 1) * block], wq);
                assert_eq!(mem_decoded[iter][kk], truth, "iter {iter} block {kk}");
            }
        }

        // Byte accounting is live on both backends.
        let (ms, mr) = mem.wire_bytes();
        let (ts, tr) = tcp.wire_bytes();
        assert!(ms > 0 && mr > 0, "memory backend must account bytes");
        assert!(ts > 0 && tr > 0, "tcp backend must account bytes");
    }
}

/// Early exit: with one worker slowed well past the round, `collect_first`
/// must return the fastest-R subset without it — on both backends.
#[test]
fn early_exit_skips_slow_worker_on_both_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (9usize, 2usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold();
    let (rows, d) = (4usize, 6usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];
    let slow_id = 3usize;

    let mut rng = Rng::new(7);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&f.random_matrix(&mut rng, d, 1), d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    let mut slow_specs = specs(n, rows, d, &coeffs, Parallelism::Serial);
    slow_specs[slow_id].slow_ms = 150;

    let procs = spawn_workers(n);
    let backends: Vec<(&str, Cluster)> = vec![
        ("memory", Cluster::spawn(slow_specs.clone()).unwrap()),
        ("tcp", Cluster::connect(slow_specs, &tcp_config(&procs)).unwrap()),
    ];
    for (name, mut cluster) in backends {
        cluster.load_data(x_shares.clone(), None).unwrap();
        cluster.dispatch(0, w_shares.clone()).unwrap();
        let round = cluster.collect_first(need, 0).unwrap();
        assert!(round.ok(), "{name}: {round:?}");
        assert_eq!(round.results.len(), need, "{name}");
        assert!(
            round.results.iter().all(|r| r.worker != slow_id),
            "{name}: the 150 ms straggler cannot be in the fastest-{need} subset"
        );
    }
}

/// Late-result draining: a straggler's stale result lands between rounds
/// and must be drained (counted, never decoded) by the next round — on
/// both backends.
#[test]
fn late_results_are_drained_on_both_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (9usize, 2usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold();
    let (rows, d) = (4usize, 6usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(8);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&f.random_matrix(&mut rng, d, 1), d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    let mut slow_specs = specs(n, rows, d, &coeffs, Parallelism::Serial);
    slow_specs[0].slow_ms = 120;

    let procs = spawn_workers(n);
    let backends: Vec<(&str, Cluster)> = vec![
        ("memory", Cluster::spawn(slow_specs.clone()).unwrap()),
        ("tcp", Cluster::connect(slow_specs, &tcp_config(&procs)).unwrap()),
    ];
    for (name, mut cluster) in backends {
        cluster.load_data(x_shares.clone(), None).unwrap();
        cluster.dispatch(0, w_shares.clone()).unwrap();
        let r0 = cluster.collect_first(need, 0).unwrap();
        assert!(r0.ok(), "{name}");
        // Let the straggler's iteration-0 result land in the channel.
        std::thread::sleep(Duration::from_millis(300));
        cluster.dispatch(1, w_shares.clone()).unwrap();
        let r1 = cluster.collect_first(need, 1).unwrap();
        assert!(r1.ok(), "{name}");
        assert!(
            r1.late_drained >= 1,
            "{name}: stale result must be drained, got {r1:?}"
        );
        assert!(r1.failures.is_empty(), "{name}: a late Ok is not a failure");
    }
}

/// Mid-round worker death lands in `failures`, never deadlocks, and the
/// cluster keeps training: on the in-memory backend via an injected fault,
/// on TCP by killing the real worker process between iterations.
#[test]
fn mid_round_death_is_counted_and_survivable_on_both_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (5usize, 1usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold(); // 4 → slack 1
    assert_eq!(n - need, 1);
    let (rows, d) = (4usize, 6usize);
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(9);
    let xq = f.random_matrix(&mut rng, rows * k, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, rows * k, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let wq = f.random_matrix(&mut rng, d, 1);
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&wq, d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let wc = WorkerComputation::new(f, rows, d, coeffs.clone());
    let truth = wc.compute(&xq, &wq);

    // In-memory: worker 0 starts failing at iteration 1.
    let mut mem_specs = specs(n, rows, d, &coeffs, Parallelism::Serial);
    mem_specs[0].fail_from_iter = Some(1);
    let mut mem = Cluster::spawn(mem_specs).unwrap();

    // TCP: same topology, worker 0's *process* is killed after iteration 0.
    let mut procs = spawn_workers(n);
    let mut tcp =
        Cluster::connect(specs(n, rows, d, &coeffs, Parallelism::Serial), &tcp_config(&procs))
            .unwrap();

    for (name, cluster) in [("memory", &mut mem), ("tcp", &mut tcp)] {
        cluster.load_data(x_shares.clone(), None).unwrap();
        cluster.dispatch(0, w_shares.clone()).unwrap();
        let r0 = cluster.collect_first(need, 0).unwrap();
        assert!(r0.ok(), "{name}: healthy round must succeed");
    }

    let _ = procs[0].child.kill();
    let _ = procs[0].child.wait();

    for (name, cluster) in [("memory", &mut mem), ("tcp", &mut tcp)] {
        let mut dec = Decoder::new(f, params, enc.points.clone());
        for iter in 1..=2u64 {
            cluster.dispatch(iter, w_shares.clone()).unwrap();
            let round = cluster.collect_first(need, iter).unwrap();
            assert!(round.ok(), "{name} iter {iter}: {round:?}");
            assert!(
                !round.failures.is_empty(),
                "{name} iter {iter}: the dead worker must be counted, got {round:?}"
            );
            assert!(
                round.results.iter().all(|r| r.worker != 0),
                "{name} iter {iter}: dead worker cannot produce results"
            );
            let subset: Vec<WorkerResult> = round
                .results
                .iter()
                .take(need)
                .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
                .collect();
            let decoded = dec.decode(&subset, d).unwrap();
            assert_eq!(decoded[0], truth, "{name} iter {iter}: decode still exact");
        }
    }
}

/// Total loss: every worker dies mid-run. The collection must still
/// terminate with a fully-accounted round — all N workers charged a
/// structured failure, zero results, no deadlock, no panic — on both
/// backends. (The session layer then turns this shortfall into
/// `TrainError::TooManyFailures` or, when armed, approximate decode.)
#[test]
fn total_worker_loss_terminates_with_structured_failures_on_both_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (4usize, 1usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold(); // 4 → zero slack
    assert_eq!(need, n);
    let (rows, d) = (4usize, 6usize);
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(11);
    let xq = f.random_matrix(&mut rng, rows * k, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, rows * k, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&f.random_matrix(&mut rng, d, 1), d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    // In-memory: every worker starts failing at iteration 1.
    let mut mem_specs = specs(n, rows, d, &coeffs, Parallelism::Serial);
    for s in mem_specs.iter_mut() {
        s.fail_from_iter = Some(1);
    }
    let mut mem = Cluster::spawn(mem_specs).unwrap();

    // TCP: every worker *process* is killed after iteration 0.
    let mut procs = spawn_workers(n);
    let mut tcp =
        Cluster::connect(specs(n, rows, d, &coeffs, Parallelism::Serial), &tcp_config(&procs))
            .unwrap();

    for (name, cluster) in [("memory", &mut mem), ("tcp", &mut tcp)] {
        cluster.load_data(x_shares.clone(), None).unwrap();
        cluster.dispatch(0, w_shares.clone()).unwrap();
        let r0 = cluster.collect_first(need, 0).unwrap();
        assert!(r0.ok(), "{name}: healthy round must succeed");
    }
    for p in procs.iter_mut() {
        let _ = p.child.kill();
        let _ = p.child.wait();
    }

    for (name, cluster) in [("memory", &mut mem), ("tcp", &mut tcp)] {
        cluster.dispatch(1, w_shares.clone()).unwrap();
        // The deadline is a belt only: dead sockets EOF promptly, so the
        // Down events (or send-failure down marks) complete the round on
        // their own long before it fires.
        let round = cluster
            .collect_deadline(need, 1, &Deadline::after_ms(10_000))
            .unwrap();
        assert!(round.complete(), "{name}: round must terminate, got {round:?}");
        assert!(!round.ok(), "{name}: total loss cannot reach the threshold");
        assert!(round.results.is_empty(), "{name}: dead workers cannot answer");
        assert_eq!(
            round.failures.len(),
            n,
            "{name}: every worker must be charged a structured failure: {:?}",
            round.failures
        );
    }
}

/// Spawn a replacement `codedml --worker` bound to the *exact* address a
/// killed worker held, so the master's supervisor can redial it. std's
/// `TcpListener::bind` sets SO_REUSEADDR on Unix, so the port is
/// rebindable as soon as the old listener is gone; retry briefly while
/// the kernel reaps the killed process.
fn spawn_worker_at(addr: &str) -> WorkerProc {
    for _ in 0..50 {
        let mut child = Command::new(env!("CARGO_BIN_EXE_codedml"))
            .args(["--worker", "--listen", addr])
            .stdout(Stdio::piped())
            .stderr(Stdio::null())
            .spawn()
            .unwrap();
        let stdout = child.stdout.take().unwrap();
        let mut line = String::new();
        let _ = BufReader::new(stdout).read_line(&mut line);
        if line.contains(addr) {
            return WorkerProc { child, addr: addr.to_string() };
        }
        let _ = child.kill();
        let _ = child.wait();
        std::thread::sleep(Duration::from_millis(100));
    }
    panic!("could not rebind a replacement worker at {addr}");
}

/// Recovery conformance (tentpole): a TCP worker process is killed
/// mid-training and a replacement is started on the same address. The
/// supervisor redials it, re-ships its encoded share, re-dispatches the
/// in-flight iteration, and the resumed round completes — and because
/// the replacement holds the predecessor's exact share, every decoded
/// gradient is bit-identical to an uninterrupted in-memory run.
#[test]
fn killed_tcp_worker_respawns_and_trajectory_matches_uninterrupted_run() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (4usize, 1usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold(); // 4 → zero slack: healing is the
    assert_eq!(need, n); // only way a short round can complete
    let (rows, d) = (4usize, 6usize);
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(12);
    let xq = f.random_matrix(&mut rng, rows * k, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, rows * k, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let iters = 3usize;
    let mut w_shares_per_iter = Vec::new();
    for _ in 0..iters {
        let shares: Vec<Vec<u64>> = enc
            .encode_weights(&f.random_matrix(&mut rng, d, 1), d, 1, &mut rng)
            .into_iter()
            .map(|s| s.data)
            .collect();
        w_shares_per_iter.push(shares);
    }

    // Uninterrupted in-memory reference run.
    let mut mem = Cluster::spawn(specs(n, rows, d, &coeffs, Parallelism::Serial)).unwrap();
    mem.load_data(x_shares.clone(), None).unwrap();
    let reference = run_rounds(&mut mem, &enc, f, params, d, &w_shares_per_iter);

    // TCP run with a mid-training kill + same-address respawn.
    let worker_specs = specs(n, rows, d, &coeffs, Parallelism::Serial);
    let mut procs = spawn_workers(n);
    let mut cfg = tcp_config(&procs);
    cfg.tcp.connect_timeout_ms = 2000;
    cfg.tcp.connect_retries = 5;
    cfg.tcp.connect_backoff_ms = 10;
    let mut tcp = Cluster::connect(worker_specs.clone(), &cfg).unwrap();
    tcp.load_data(x_shares.clone(), None).unwrap();
    let mut sup = Supervisor::new(worker_specs, x_shares.clone(), None, 1);
    let mut dec = Decoder::new(f, params, enc.points.clone());
    let mut decoded = Vec::new();

    // Iteration 0: healthy.
    tcp.dispatch(0, w_shares_per_iter[0].clone()).unwrap();
    let r0 = tcp.collect_first(need, 0).unwrap();
    assert!(r0.ok(), "{r0:?}");
    let subset: Vec<WorkerResult> = r0
        .results
        .iter()
        .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
        .collect();
    decoded.push(dec.decode(&subset, d).unwrap());

    // Kill worker 1's process, then bring a replacement up on its port.
    let victim_addr = procs[1].addr.clone();
    let _ = procs[1].child.kill();
    let _ = procs[1].child.wait();
    procs[1] = spawn_worker_at(&victim_addr);

    // Iteration 1 falls short (zero slack), the supervisor heals it
    // mid-round, and the resumed collection completes exactly.
    tcp.dispatch(1, w_shares_per_iter[1].clone()).unwrap();
    let mut r1 = tcp
        .collect_deadline(need, 1, &Deadline::after_ms(10_000))
        .unwrap();
    assert!(!r1.ok(), "zero slack: the killed worker must leave iter 1 short");
    assert!(r1.failures.iter().any(|(w, _)| *w == 1), "{:?}", r1.failures);
    sup.observe_round(&r1);
    let outcomes = sup.heal(&mut tcp, &mut r1, &w_shares_per_iter[1]);
    assert_eq!(outcomes.len(), 1);
    assert_eq!(outcomes[0].worker, 1);
    assert!(outcomes[0].result.is_ok(), "redial failed: {:?}", outcomes[0].result);
    assert!(outcomes[0].redispatched, "mid-round heal must re-dispatch");
    tcp.collect_resume(&mut r1, &Deadline::after_ms(10_000)).unwrap();
    assert!(r1.ok(), "healed round must complete: {:?}", r1.failures);
    assert_eq!(r1.healed.len(), 1, "the death stays on the books");
    let subset: Vec<WorkerResult> = r1
        .results
        .iter()
        .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
        .collect();
    decoded.push(dec.decode(&subset, d).unwrap());

    // Iteration 2: the replacement is a full citizen again.
    tcp.dispatch(2, w_shares_per_iter[2].clone()).unwrap();
    let r2 = tcp.collect_first(need, 2).unwrap();
    assert!(r2.ok(), "{r2:?}");
    assert!(r2.results.iter().any(|r| r.worker == 1), "replacement must answer");
    let subset: Vec<WorkerResult> = r2
        .results
        .iter()
        .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
        .collect();
    decoded.push(dec.decode(&subset, d).unwrap());

    assert_eq!(sup.respawns, 1);
    assert_eq!(
        decoded, reference,
        "kill + respawn must not perturb the trajectory: LCC decoding is \
         exact for any fastest-R subset and the replacement holds the \
         predecessor's share"
    );
}
