//! Backend-conformance suite for the `Transport` seam: every backend —
//! the in-memory channel default and the TCP multi-process one — must
//! drive the identical dispatch/collect contract. Because LCC decoding is
//! exact for *any* fastest-R subset, the decoded gradients must be
//! bit-identical across backends at every thread count, no matter which
//! workers happened to answer first or over which medium the shares
//! travelled. The suite also pins the streaming-round behaviours
//! (early exit at R, late-result draining, mid-round worker death) to
//! both backends so a new transport cannot regress them silently.
//!
//! TCP scenarios spawn real `codedml --worker` processes on loopback via
//! `CARGO_BIN_EXE_codedml`, exactly as a deployment would.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use codedml::cluster::transport::TcpConfig;
use codedml::cluster::{Cluster, TransportConfig, TransportKind, WorkerOp, WorkerSpec};
use codedml::coding::{CodingParams, Decoder, Encoder, WorkerResult};
use codedml::compute::WorkerComputation;
use codedml::field::{PrimeField, PAPER_PRIME};
use codedml::util::{Parallelism, Rng};

/// A `codedml --worker` child process bound to an ephemeral loopback
/// port. Killed and reaped on drop so a failing assertion can't leak
/// processes into the test runner.
struct WorkerProc {
    child: Child,
    addr: String,
}

impl Drop for WorkerProc {
    fn drop(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }
}

fn spawn_worker() -> WorkerProc {
    let mut child = Command::new(env!("CARGO_BIN_EXE_codedml"))
        .args(["--worker", "--listen", "127.0.0.1:0"])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();
    // The worker prints exactly one banner line before accepting:
    //   worker listening on 127.0.0.1:PORT
    let stdout = child.stdout.take().unwrap();
    let mut line = String::new();
    BufReader::new(stdout).read_line(&mut line).unwrap();
    let addr = line.trim().rsplit(' ').next().unwrap_or("").to_string();
    assert!(addr.contains(':'), "unexpected worker banner: {line:?}");
    WorkerProc { child, addr }
}

fn spawn_workers(n: usize) -> Vec<WorkerProc> {
    (0..n).map(|_| spawn_worker()).collect()
}

fn tcp_config(procs: &[WorkerProc]) -> TransportConfig {
    TransportConfig {
        kind: TransportKind::Tcp,
        tcp: TcpConfig {
            workers: procs.iter().map(|p| p.addr.clone()).collect(),
            ..TcpConfig::default()
        },
    }
}

fn specs(n: usize, rows: usize, d: usize, coeffs: &[u64], par: Parallelism) -> Vec<WorkerSpec> {
    let f = PrimeField::new(PAPER_PRIME);
    (0..n)
        .map(|id| WorkerSpec {
            id,
            kind: codedml::runtime::BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            field: f,
            rows,
            d,
            coeffs: coeffs.to_vec(),
            op: WorkerOp::Logistic,
            fail_from_iter: None,
            slow_ms: 0,
            par,
        })
        .collect()
}

/// Run `iters` dispatch/collect/decode rounds on a cluster and return the
/// decoded gradient blocks per iteration, always decoding the fastest-R
/// subset in arrival order.
fn run_rounds(
    cluster: &mut Cluster,
    enc: &Encoder,
    f: PrimeField,
    params: CodingParams,
    d: usize,
    w_shares_per_iter: &[Vec<Vec<u64>>],
) -> Vec<Vec<Vec<u64>>> {
    let need = params.recovery_threshold();
    let mut dec = Decoder::new(f, params, enc.points.clone());
    let mut decoded = Vec::new();
    for (iter, w_shares) in w_shares_per_iter.iter().enumerate() {
        cluster.dispatch(iter as u64, w_shares.clone()).unwrap();
        let round = cluster.collect_first(need, iter as u64).unwrap();
        assert!(round.ok(), "iter {iter}: {round:?}");
        let subset: Vec<WorkerResult> = round
            .results
            .iter()
            .take(need)
            .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
            .collect();
        decoded.push(dec.decode(&subset, d).unwrap());
    }
    decoded
}

/// Tentpole conformance: with identical shares, the decoded gradient of
/// every iteration is bit-identical on the in-memory backend, on the TCP
/// backend with real worker processes, and to the ground-truth direct
/// computation — at serial and multi-threaded worker parallelism alike.
#[test]
fn decoded_gradients_bit_identical_across_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (9usize, 2usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    assert!(n - params.recovery_threshold() >= 2, "want straggler slack");
    let (rows, d) = (4usize, 6usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(42);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    let iters = 3usize;
    let mut wqs = Vec::new();
    let mut w_shares_per_iter = Vec::new();
    for _ in 0..iters {
        let wq = f.random_matrix(&mut rng, d, 1);
        let shares: Vec<Vec<u64>> = enc
            .encode_weights(&wq, d, 1, &mut rng)
            .into_iter()
            .map(|s| s.data)
            .collect();
        wqs.push(wq);
        w_shares_per_iter.push(shares);
    }
    let wc = WorkerComputation::new(f, rows, d, coeffs.clone());

    for par in [Parallelism::Serial, Parallelism::from_count(2)] {
        let mut mem = Cluster::spawn(specs(n, rows, d, &coeffs, par)).unwrap();
        mem.load_data(x_shares.clone(), None).unwrap();
        let mem_decoded = run_rounds(&mut mem, &enc, f, params, d, &w_shares_per_iter);

        let procs = spawn_workers(n);
        let mut tcp = Cluster::connect(specs(n, rows, d, &coeffs, par), &tcp_config(&procs)).unwrap();
        assert_eq!(tcp.transport_name(), "tcp");
        tcp.load_data(x_shares.clone(), None).unwrap();
        let tcp_decoded = run_rounds(&mut tcp, &enc, f, params, d, &w_shares_per_iter);

        assert_eq!(mem_decoded, tcp_decoded, "backends diverged at par {par:?}");

        // Both equal ground truth on the true blocks, every iteration.
        let block = rows * d;
        for (iter, wq) in wqs.iter().enumerate() {
            for kk in 0..k {
                let truth = wc.compute(&xq[kk * block..(kk + 1) * block], wq);
                assert_eq!(mem_decoded[iter][kk], truth, "iter {iter} block {kk}");
            }
        }

        // Byte accounting is live on both backends.
        let (ms, mr) = mem.wire_bytes();
        let (ts, tr) = tcp.wire_bytes();
        assert!(ms > 0 && mr > 0, "memory backend must account bytes");
        assert!(ts > 0 && tr > 0, "tcp backend must account bytes");
    }
}

/// Early exit: with one worker slowed well past the round, `collect_first`
/// must return the fastest-R subset without it — on both backends.
#[test]
fn early_exit_skips_slow_worker_on_both_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (9usize, 2usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold();
    let (rows, d) = (4usize, 6usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];
    let slow_id = 3usize;

    let mut rng = Rng::new(7);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&f.random_matrix(&mut rng, d, 1), d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    let mut slow_specs = specs(n, rows, d, &coeffs, Parallelism::Serial);
    slow_specs[slow_id].slow_ms = 150;

    let procs = spawn_workers(n);
    let backends: Vec<(&str, Cluster)> = vec![
        ("memory", Cluster::spawn(slow_specs.clone()).unwrap()),
        ("tcp", Cluster::connect(slow_specs, &tcp_config(&procs)).unwrap()),
    ];
    for (name, mut cluster) in backends {
        cluster.load_data(x_shares.clone(), None).unwrap();
        cluster.dispatch(0, w_shares.clone()).unwrap();
        let round = cluster.collect_first(need, 0).unwrap();
        assert!(round.ok(), "{name}: {round:?}");
        assert_eq!(round.results.len(), need, "{name}");
        assert!(
            round.results.iter().all(|r| r.worker != slow_id),
            "{name}: the 150 ms straggler cannot be in the fastest-{need} subset"
        );
    }
}

/// Late-result draining: a straggler's stale result lands between rounds
/// and must be drained (counted, never decoded) by the next round — on
/// both backends.
#[test]
fn late_results_are_drained_on_both_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (9usize, 2usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold();
    let (rows, d) = (4usize, 6usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(8);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&f.random_matrix(&mut rng, d, 1), d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    let mut slow_specs = specs(n, rows, d, &coeffs, Parallelism::Serial);
    slow_specs[0].slow_ms = 120;

    let procs = spawn_workers(n);
    let backends: Vec<(&str, Cluster)> = vec![
        ("memory", Cluster::spawn(slow_specs.clone()).unwrap()),
        ("tcp", Cluster::connect(slow_specs, &tcp_config(&procs)).unwrap()),
    ];
    for (name, mut cluster) in backends {
        cluster.load_data(x_shares.clone(), None).unwrap();
        cluster.dispatch(0, w_shares.clone()).unwrap();
        let r0 = cluster.collect_first(need, 0).unwrap();
        assert!(r0.ok(), "{name}");
        // Let the straggler's iteration-0 result land in the channel.
        std::thread::sleep(Duration::from_millis(300));
        cluster.dispatch(1, w_shares.clone()).unwrap();
        let r1 = cluster.collect_first(need, 1).unwrap();
        assert!(r1.ok(), "{name}");
        assert!(
            r1.late_drained >= 1,
            "{name}: stale result must be drained, got {r1:?}"
        );
        assert!(r1.failures.is_empty(), "{name}: a late Ok is not a failure");
    }
}

/// Mid-round worker death lands in `failures`, never deadlocks, and the
/// cluster keeps training: on the in-memory backend via an injected fault,
/// on TCP by killing the real worker process between iterations.
#[test]
fn mid_round_death_is_counted_and_survivable_on_both_backends() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (5usize, 1usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold(); // 4 → slack 1
    assert_eq!(n - need, 1);
    let (rows, d) = (4usize, 6usize);
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(9);
    let xq = f.random_matrix(&mut rng, rows * k, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, rows * k, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let wq = f.random_matrix(&mut rng, d, 1);
    let w_shares: Vec<Vec<u64>> = enc
        .encode_weights(&wq, d, 1, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();
    let wc = WorkerComputation::new(f, rows, d, coeffs.clone());
    let truth = wc.compute(&xq, &wq);

    // In-memory: worker 0 starts failing at iteration 1.
    let mut mem_specs = specs(n, rows, d, &coeffs, Parallelism::Serial);
    mem_specs[0].fail_from_iter = Some(1);
    let mut mem = Cluster::spawn(mem_specs).unwrap();

    // TCP: same topology, worker 0's *process* is killed after iteration 0.
    let mut procs = spawn_workers(n);
    let mut tcp =
        Cluster::connect(specs(n, rows, d, &coeffs, Parallelism::Serial), &tcp_config(&procs))
            .unwrap();

    for (name, cluster) in [("memory", &mut mem), ("tcp", &mut tcp)] {
        cluster.load_data(x_shares.clone(), None).unwrap();
        cluster.dispatch(0, w_shares.clone()).unwrap();
        let r0 = cluster.collect_first(need, 0).unwrap();
        assert!(r0.ok(), "{name}: healthy round must succeed");
    }

    let _ = procs[0].child.kill();
    let _ = procs[0].child.wait();

    for (name, cluster) in [("memory", &mut mem), ("tcp", &mut tcp)] {
        let mut dec = Decoder::new(f, params, enc.points.clone());
        for iter in 1..=2u64 {
            cluster.dispatch(iter, w_shares.clone()).unwrap();
            let round = cluster.collect_first(need, iter).unwrap();
            assert!(round.ok(), "{name} iter {iter}: {round:?}");
            assert!(
                !round.failures.is_empty(),
                "{name} iter {iter}: the dead worker must be counted, got {round:?}"
            );
            assert!(
                round.results.iter().all(|r| r.worker != 0),
                "{name} iter {iter}: dead worker cannot produce results"
            );
            let subset: Vec<WorkerResult> = round
                .results
                .iter()
                .take(need)
                .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
                .collect();
            let decoded = dec.decode(&subset, d).unwrap();
            assert_eq!(decoded[0], truth, "{name} iter {iter}: decode still exact");
        }
    }
}
