//! Tier-1 enforcement of the in-repo invariant linter (`cpml-lint`).
//!
//! Two gates, both under plain `cargo test -q`:
//!
//! 1. the real source tree (`rust/src`) must be lint-clean, and
//! 2. every seeded fixture under `rust/tests/fixtures/lint/<rule-id>/`
//!    must trip *exactly* its own rule — proving each rule both fires
//!    and stays in its lane.
//!
//! Fixture files are data, not code: they are never compiled (this
//! package declares explicit test targets only), the linter just reads
//! them off disk.

use std::path::PathBuf;

use codedml::analysis::{lint, report_json, SourceTree, RULES};

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn the_source_tree_is_lint_clean() {
    let root = repo_root().join("rust").join("src");
    let tree = SourceTree::scan(&root).expect("scan rust/src");
    assert!(tree.files.len() > 20, "walker found only {} files", tree.files.len());
    let findings = lint(&tree);
    assert!(
        findings.is_empty(),
        "rust/src has lint findings — fix them or add a justified \
         `// lint: allow(<rule>): <reason>`:\n{}",
        findings.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}

#[test]
fn every_fixture_trips_exactly_its_rule() {
    let fixtures = repo_root().join("rust").join("tests").join("fixtures").join("lint");
    for rule in RULES {
        let root = fixtures.join(rule.id);
        let tree = SourceTree::scan(&root)
            .unwrap_or_else(|e| panic!("scan fixture {}: {e}", rule.id));
        let findings = lint(&tree);
        assert!(
            !findings.is_empty(),
            "fixture for {} produced no findings — the rule is dead",
            rule.id
        );
        for f in &findings {
            assert_eq!(
                f.rule, rule.id,
                "fixture for {} tripped a foreign rule: {f}",
                rule.id
            );
        }
        // The JSON report counts the violation under the right id.
        let ids: Vec<&str> = RULES.iter().map(|r| r.id).collect();
        let doc = report_json(&ids, &findings);
        assert!(
            doc.get("by_rule").unwrap().get(rule.id).unwrap().as_u64().unwrap() >= 1,
            "JSON report missing count for {}",
            rule.id
        );
        assert_eq!(
            doc.get("total").unwrap().as_u64().unwrap(),
            findings.len() as u64
        );
    }
}

#[test]
fn findings_carry_file_line_and_message() {
    let fixtures = repo_root().join("rust").join("tests").join("fixtures").join("lint");
    let root = fixtures.join("no-hardware-modulo");
    let tree = SourceTree::scan(&root).expect("scan fixture");
    let findings = lint(&tree);
    assert_eq!(findings.len(), 1);
    let f = &findings[0];
    assert_eq!(f.file, "field/reduce.rs");
    assert_eq!(f.line, 7, "the `%` sits on line 7 of the fixture");
    let rendered = format!("{f}");
    assert!(
        rendered.starts_with("field/reduce.rs:7 no-hardware-modulo "),
        "compiler-style rendering, got: {rendered}"
    );
}
