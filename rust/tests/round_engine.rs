//! The streaming round engine end to end: with a real slow worker in the
//! cluster, `collect_first` must (a) return without waiting for it,
//! (b) decode bit-identically to a full collection restricted to the same
//! subset, and (c) drain the slow worker's late results without ever
//! deadlocking or leaking them into a later iteration's decode.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use codedml::cluster::{Cluster, NetworkModel, StragglerModel, WorkerOp, WorkerSpec};
use codedml::coding::{CodingParams, Decoder, Encoder, WorkerResult};
use codedml::compute::WorkerComputation;
use codedml::coordinator::{CodedMlConfig, CodedMlSession};
use codedml::data::synthetic_3v7;
use codedml::field::{PrimeField, PAPER_PRIME};
use codedml::util::{Parallelism, Rng};

fn specs(n: usize, rows: usize, d: usize, coeffs: Vec<u64>, slow: &[usize]) -> Vec<WorkerSpec> {
    let f = PrimeField::new(PAPER_PRIME);
    (0..n)
        .map(|id| WorkerSpec {
            id,
            session: 0,
            kind: codedml::runtime::BackendKind::Native,
            artifact_dir: PathBuf::from("artifacts"),
            field: f,
            rows,
            d,
            coeffs: coeffs.clone(),
            op: WorkerOp::Logistic,
            fail_from_iter: None,
            slow_ms: if slow.contains(&id) { 80 } else { 0 },
            par: Parallelism::Serial,
        })
        .collect()
}

/// Early-exit decoding must be bit-identical to the old full-collection
/// path on the same subset — run both against one dispatch, iteration by
/// iteration, and also check against ground truth on the true blocks.
#[test]
fn collect_first_decodes_bit_identically_to_full_collection() {
    let f = PrimeField::new(PAPER_PRIME);
    let (n, k, t) = (13usize, 3usize, 1usize);
    let params = CodingParams::new(n, k, t, 1).unwrap();
    let need = params.recovery_threshold(); // 10 → slack 3
    let (rows, d) = (4usize, 6usize);
    let m = rows * k;
    let coeffs = vec![3u64, 7];

    let mut rng = Rng::new(5);
    let xq = f.random_matrix(&mut rng, m, d);
    let enc = Encoder::new(f, params);
    let x_shares: Vec<Vec<u64>> = enc
        .encode_dataset(&xq, m, d, &mut rng)
        .into_iter()
        .map(|s| s.data)
        .collect();

    // Two identical clusters over the same shares, each with worker 7
    // slowed by 80 ms: A exits early, B collects everyone.
    let mut early = Cluster::spawn(specs(n, rows, d, coeffs.clone(), &[7])).unwrap();
    let mut full = Cluster::spawn(specs(n, rows, d, coeffs.clone(), &[7])).unwrap();
    early.load_data(x_shares.clone(), None).unwrap();
    full.load_data(x_shares.clone(), None).unwrap();

    let wc = WorkerComputation::new(f, rows, d, coeffs);
    let mut dec_early = Decoder::new(f, params, enc.points.clone());
    let mut dec_full = Decoder::new(f, params, enc.points.clone());

    for iter in 0..3u64 {
        let wq = f.random_matrix(&mut rng, d, 1);
        let w_shares: Vec<Vec<u64>> = enc
            .encode_weights(&wq, d, 1, &mut rng)
            .into_iter()
            .map(|s| s.data)
            .collect();

        early.dispatch(iter, w_shares.clone()).unwrap();
        let t0 = Instant::now();
        let round = early.collect_first(need, iter).unwrap();
        assert!(round.ok());
        assert!(
            t0.elapsed() < Duration::from_millis(60),
            "collection must not wait out the 80 ms straggler"
        );
        let subset: Vec<WorkerResult> = round
            .results
            .iter()
            .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
            .collect();
        let decoded_early = dec_early.decode(&subset, d).unwrap();

        // Full collection on the twin cluster, restricted to the same
        // worker subset (this is exactly what the deleted lock-step path
        // decoded) — must be bit-identical.
        full.dispatch(iter, w_shares).unwrap();
        let all = full.collect_first(n, iter).unwrap();
        assert_eq!(all.results.len(), n);
        let used: Vec<usize> = subset.iter().map(|r| r.worker).collect();
        let same_subset: Vec<WorkerResult> = all
            .results
            .iter()
            .filter(|r| used.contains(&r.worker))
            .map(|r| WorkerResult { worker: r.worker, data: r.data.clone().unwrap() })
            .collect();
        let decoded_full = dec_full.decode(&same_subset, d).unwrap();
        assert_eq!(decoded_early, decoded_full, "iter {iter}");

        // And both equal ground truth on the true blocks.
        let block = rows * d;
        for kk in 0..k {
            let truth = wc.compute(&xq[kk * block..(kk + 1) * block], &wq);
            assert_eq!(decoded_early[kk], truth, "iter {iter} block {kk}");
        }
    }
}

/// Late results must be drained between iterations — never decoded into a
/// later round — and training with a real slow machine must produce the
/// bit-identical trajectory of a healthy run (LCC decoding is exact for
/// any arrival subset).
#[test]
fn slow_worker_late_results_are_drained_not_decoded() {
    let train = synthetic_3v7(120, 17);
    let base = CodedMlConfig {
        n: 13, // threshold 10 → slack 3
        k: 3,
        t: 1,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..Default::default()
    };

    let mut reference = CodedMlSession::new(base.clone(), &train).unwrap();
    let slow_cfg = CodedMlConfig { chaos_slow_workers: 1, chaos_slow_ms: 60, ..base };
    let mut slow = CodedMlSession::new(slow_cfg, &train).unwrap();

    // Step both; then give the slow worker time to land its stale result
    // so the next round must drain it.
    for _ in 0..2 {
        reference.step().unwrap();
        slow.step().unwrap();
    }
    std::thread::sleep(Duration::from_millis(200));
    for _ in 0..2 {
        reference.step().unwrap();
        slow.step().unwrap();
    }

    assert_eq!(
        reference.w, slow.w,
        "slow machine must not change the trajectory, only who is decoded"
    );
    let (failures, late) = slow.round_stats();
    assert_eq!(failures, 0);
    assert!(late > 0, "stale results must be drained and counted");
    let (rf, rl) = reference.round_stats();
    assert_eq!((rf, rl), (0, 0));
}

/// The engine's wall time is bounded by the fastest-R subset: a training
/// step with one worker slowed 60 ms completes in well under 60 ms.
#[test]
fn step_wall_time_bounded_by_fastest_subset() {
    let train = synthetic_3v7(60, 19);
    let cfg = CodedMlConfig {
        n: 13,
        k: 3,
        t: 1,
        chaos_slow_workers: 1,
        chaos_slow_ms: 60,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..Default::default()
    };
    let mut sess = CodedMlSession::new(cfg, &train).unwrap();
    // Warm up thread scheduling, then time a step.
    sess.step().unwrap();
    let t0 = Instant::now();
    sess.step().unwrap();
    assert!(
        t0.elapsed() < Duration::from_millis(50),
        "step took {:?}, gated by the slow worker",
        t0.elapsed()
    );
}
