//! Integration: the AOT JAX/Pallas artifact and the native rust backend
//! must agree bit-for-bit on every worker_f shape in the manifest.
//!
//! Requires `make artifacts`; tests skip (with a loud note) if the
//! artifact directory is absent so `cargo test` stays runnable pre-build.

use std::path::PathBuf;

use codedml::compute::WorkerComputation;
use codedml::field::PrimeField;
use codedml::runtime::{ArtifactKind, XlaRuntime};
use codedml::util::Rng;

fn artifact_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        None
    }
}

#[test]
fn xla_equals_native_on_every_manifest_shape() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let entries: Vec<_> = rt
        .manifest()
        .entries
        .iter()
        .filter(|e| e.kind == ArtifactKind::WorkerF)
        .cloned()
        .collect();
    assert!(!entries.is_empty(), "manifest has no worker_f artifacts");
    let mut rng = Rng::new(2024);
    for e in entries {
        let field = PrimeField::new(e.p);
        let x = field.random_matrix(&mut rng, e.rows, e.d);
        let w = field.random_matrix(&mut rng, e.d, e.r);
        let coeffs: Vec<u64> = (0..=e.r).map(|_| field.random(&mut rng)).collect();

        let xla_out = rt
            .worker_f(&x, &w, &coeffs, e.rows, e.d, e.p)
            .unwrap_or_else(|err| panic!("xla {}: {err}", e.name));
        let native = WorkerComputation::new(field, e.rows, e.d, coeffs.clone());
        let native_out = native.compute(&x, &w);
        assert_eq!(xla_out, native_out, "mismatch on {}", e.name);
    }
}

#[test]
fn xla_executable_cache_prevents_recompilation() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let e = rt
        .manifest()
        .find_worker(32, 64, 1, 15485863)
        .expect("quickstart shape present")
        .clone();
    let field = PrimeField::new(e.p);
    let mut rng = Rng::new(7);
    let x = field.random_matrix(&mut rng, e.rows, e.d);
    let w = field.random_matrix(&mut rng, e.d, e.r);
    let c: Vec<u64> = (0..=e.r).map(|_| field.random(&mut rng)).collect();
    for _ in 0..5 {
        rt.worker_f(&x, &w, &c, e.rows, e.d, e.p).unwrap();
    }
    assert_eq!(rt.compile_count(), 1, "request path must not recompile");
}

#[test]
fn lr_step_artifact_matches_native_model() {
    let Some(dir) = artifact_dir() else { return };
    let rt = XlaRuntime::new(&dir).expect("runtime");
    let (m, d) = (256, 784);
    if rt.manifest().find_lr_step(m, d).is_none() {
        eprintln!("SKIP: lr_step artifact missing");
        return;
    }
    let train = codedml::data::synthetic_3v7(m, 5);
    let mut model = codedml::model::LogisticRegression::new(d);
    let eta = 0.5;
    let (w_xla, loss_xla) = rt.lr_step(&train.x, &train.y, &model.w, eta, m, d).unwrap();
    // Native reference.
    let loss_native = model.loss(&train);
    model.step(&train, eta);
    assert!((loss_xla - loss_native).abs() < 1e-9, "{loss_xla} vs {loss_native}");
    for (a, b) in w_xla.iter().zip(model.w.iter()) {
        assert!((a - b).abs() < 1e-9, "{a} vs {b}");
    }
}

#[test]
fn cluster_trains_with_xla_backend() {
    let Some(dir) = artifact_dir() else { return };
    // 64 rows/block × K=2 = 128 train rows at d=784 (artifact shape).
    use codedml::cluster::{NetworkModel, StragglerModel};
    use codedml::coordinator::{CodedMlConfig, CodedMlSession};
    use codedml::runtime::BackendKind;
    let train = codedml::data::synthetic_3v7(128, 3);
    let cfg = CodedMlConfig {
        n: 7,
        k: 2,
        t: 1,
        backend: BackendKind::Xla,
        artifact_dir: dir,
        net: NetworkModel::free(),
        straggler: StragglerModel::none(),
        ..Default::default()
    };
    let mut sess = CodedMlSession::new(cfg.clone(), &train).unwrap();
    let report = sess.train(5, None).unwrap();
    assert!(report.final_loss().unwrap() < report.iterations[0].train_loss);

    // And the trajectory matches the native backend exactly (same seed).
    let cfg_native = CodedMlConfig {
        backend: BackendKind::Native,
        ..cfg
    };
    let mut sess_n = CodedMlSession::new(cfg_native, &train).unwrap();
    let report_n = sess_n.train(5, None).unwrap();
    for (a, b) in report.weights.iter().zip(report_n.weights.iter()) {
        assert_eq!(a, b, "xla and native trajectories must be identical");
    }
}
