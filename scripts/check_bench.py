#!/usr/bin/env python3
"""Gate bench JSON outputs, dispatching on the file name.

Usage: check_bench.py [BENCH_<target>.json]

BENCH_coding.json (default) — the NTT path must engage and win:
  * the "ntt backend engaged" metric row must exist and equal 1 — i.e.
    the auto backend must not silently fall back to dense on an
    NTT-friendly modulus;
  * the combined "ntt vs dense encode+decode ... [speedup x]" row must
    exist and exceed 1.0 — i.e. the fast path must stay fast.

BENCH_supervisor.json — fault tolerance must be strictly passive on a
healthy pool, and actually engage under chaos:
  * every "... (zero chaos)" counter (approx rounds, respawns,
    deadline-expired rounds) must be exactly 0 — degraded mode engaging
    with no fault injected is a correctness regression, not a perf one;
  * "respawns (healed run)" must be > 0 (the heal path really ran);
  * "approx rounds (degraded run)" must be > 0 (the degraded path
    really ran).

BENCH_serve.json — multiplexing sessions over one shared pool must pay
for itself without bending a trajectory:
  * the "misrouted results (must be 0)" metric row must exist and be
    exactly 0 — a result crossing a session boundary is a correctness
    bug, whatever the speedup says;
  * the "serve: ... [speedup x]" row must exist and exceed 1.5 — with
    four sessions straggling on disjoint worker pairs, overlapping
    their waits should approach 4x; below 1.5x the scheduler is
    serializing rounds it should interleave.

Run against a fresh BENCH_JSON=1 output (see .github/workflows/ci.yml
bench-smoke and chaos jobs), not against the committed baselines in
benchmarks/baseline.
"""

import json
import os
import sys


def check_coding(rows, failures):
    engaged = [r for r in rows if r["name"].startswith("ntt backend engaged")]
    if not engaged:
        failures.append("no 'ntt backend engaged' metric row in the bench output")
    for r in engaged:
        if r.get("value") != 1:
            failures.append(
                f"{r['name']!r}: value {r.get('value')!r} — the auto backend "
                "fell back to dense on an NTT-friendly modulus"
            )

    combined = [
        r
        for r in rows
        if "ntt vs dense encode+decode" in r["name"] and "[speedup x]" in r["name"]
    ]
    if not combined:
        failures.append("no 'ntt vs dense encode+decode ... [speedup x]' row")
    for r in combined:
        speedup = r.get("value", 0.0)
        if not speedup > 1.0:
            failures.append(f"{r['name']!r}: speedup {speedup} <= 1.0")
        else:
            print(f"ok: {r['name']} = {speedup:.2f}x")


def check_supervisor(rows, failures):
    zero_chaos = [r for r in rows if "(zero chaos)" in r["name"]]
    if len(zero_chaos) < 3:
        failures.append(
            f"expected the 3 '(zero chaos)' counter rows, found {len(zero_chaos)}"
        )
    for r in zero_chaos:
        if r.get("value") != 0:
            failures.append(
                f"{r['name']!r}: value {r.get('value')!r} — degraded mode must "
                "never engage when no fault is injected"
            )
        else:
            print(f"ok: {r['name']} = 0")

    for name in ("respawns (healed run)", "approx rounds (degraded run)"):
        found = [r for r in rows if r["name"] == name]
        if not found:
            failures.append(f"no {name!r} metric row in the bench output")
        elif not found[0].get("value", 0.0) > 0:
            failures.append(
                f"{name!r}: value {found[0].get('value')!r} — the chaos run "
                "did not exercise this recovery path"
            )
        else:
            print(f"ok: {name} = {found[0]['value']:g}")


def check_serve(rows, failures):
    misrouted = [r for r in rows if r["name"].startswith("misrouted results")]
    if not misrouted:
        failures.append("no 'misrouted results' metric row in the bench output")
    for r in misrouted:
        if r.get("value") != 0:
            failures.append(
                f"{r['name']!r}: value {r.get('value')!r} — a worker result "
                "crossed a session boundary"
            )
        else:
            print(f"ok: {r['name']} = 0")

    speedups = [
        r
        for r in rows
        if "serve" in r["name"] and "[speedup x]" in r["name"]
    ]
    if not speedups:
        failures.append("no 'serve: ... [speedup x]' row in the bench output")
    for r in speedups:
        speedup = r.get("value", 0.0)
        if not speedup > 1.5:
            failures.append(
                f"{r['name']!r}: speedup {speedup} <= 1.5 — the scheduler is "
                "not overlapping the sessions' straggler waits"
            )
        else:
            print(f"ok: {r['name']} = {speedup:.2f}x")


CHECKS = {
    "BENCH_coding.json": check_coding,
    "BENCH_serve.json": check_serve,
    "BENCH_supervisor.json": check_supervisor,
}


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_coding.json"
    try:
        with open(path) as fh:
            rows = json.load(fh)["rows"]
    except (OSError, KeyError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read rows from {path}: {e}")
        return 1

    check = CHECKS.get(os.path.basename(path))
    if check is None:
        print(
            f"check_bench: no gate registered for {os.path.basename(path)!r} "
            f"(known: {', '.join(sorted(CHECKS))})"
        )
        return 1

    failures = []
    check(rows, failures)

    for msg in failures:
        print(f"check_bench: FAIL: {msg}")
    if not failures:
        print(f"check_bench: {path} ok ({len(rows)} rows)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
