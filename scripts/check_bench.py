#!/usr/bin/env python3
"""Gate the coding-bench JSON: the NTT path must actually engage and win.

Usage: check_bench.py [BENCH_coding.json]

Fails (exit 1) when:
  * the "ntt backend engaged" metric row is missing or != 1 — i.e. the
    auto backend silently fell back to dense on an NTT-friendly modulus;
  * the combined "ntt vs dense encode+decode ... [speedup x]" row is
    missing or <= 1.0 — i.e. the fast path stopped being fast.

Run against a fresh BENCH_JSON=1 output (see .github/workflows/ci.yml
bench-smoke), not against the committed baselines in benchmarks/baseline.
"""

import json
import sys


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_coding.json"
    try:
        with open(path) as fh:
            rows = json.load(fh)["rows"]
    except (OSError, KeyError, json.JSONDecodeError) as e:
        print(f"check_bench: cannot read rows from {path}: {e}")
        return 1

    failures = []

    engaged = [r for r in rows if r["name"].startswith("ntt backend engaged")]
    if not engaged:
        failures.append("no 'ntt backend engaged' metric row in the bench output")
    for r in engaged:
        if r.get("value") != 1:
            failures.append(
                f"{r['name']!r}: value {r.get('value')!r} — the auto backend "
                "fell back to dense on an NTT-friendly modulus"
            )

    combined = [
        r
        for r in rows
        if "ntt vs dense encode+decode" in r["name"] and "[speedup x]" in r["name"]
    ]
    if not combined:
        failures.append("no 'ntt vs dense encode+decode ... [speedup x]' row")
    for r in combined:
        speedup = r.get("value", 0.0)
        if not speedup > 1.0:
            failures.append(f"{r['name']!r}: speedup {speedup} <= 1.0")
        else:
            print(f"ok: {r['name']} = {speedup:.2f}x")

    for msg in failures:
        print(f"check_bench: FAIL: {msg}")
    if not failures:
        print(f"check_bench: {path} ok ({len(rows)} rows)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
